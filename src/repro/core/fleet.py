"""Vectorized struct-of-arrays device fleet — the FleetState engine.

:mod:`repro.core.energy` defines the scalar per-device reference semantics
(``DeviceState`` + ``round_cost``/``charge``).  This module holds the same
state as a struct of arrays and evaluates the paper's Eq. 3–7 fleet-wide in
a handful of batched array ops, so per-round selection + energy accounting
is O(1) kernel dispatches instead of O(n) Python loops (the RQ3/Fig. 6
scalability path: 256+ device fleets; with :mod:`repro.sharding.fleet`
the same kernels run data-parallel over a multi-device ``"fleet"`` mesh).

Two interchangeable backends share the same code (the kernels are written
against the array API common to numpy and jnp):

* ``backend="numpy"`` — float64 ops whose per-element expressions match the
  ``DeviceState`` reference path bit-for-bit (the parity contract enforced
  by ``tests/test_fleet.py``);
* ``backend="jax"`` — jnp arrays; ``FleetState`` is a registered pytree so
  the jitted kernels (``fleet_affordability_jit`` …) take and return it
  directly.  This is what ``run_simulation`` and the selectors use.

All kernels are functional: ``fleet_charge`` returns a NEW FleetState, the
input is never mutated.

``batch_size`` is accepted by the cost kernels for signature parity with
the scalar ``round_cost`` (and so selectors are priced with the full round
configuration), but — exactly like the scalar reference — the paper's
Eq. 5 cost model is batch-size-independent (samples = L_n * epochs), so it
does not enter any expression.

Public surface (one-line contracts):

* :class:`FleetState` — registered-pytree struct of ``[n]`` arrays; the
  fleet state every kernel takes and returns.
* :func:`as_fleet_state` — normalise selector input (FleetState passes
  through, DeviceState sequences get the bit-exact numpy view).
* :func:`make_fleet_state` — SoA twin of ``energy.make_fleet`` (identical
  sampled profiles for a given seed).
* :func:`sample_fleet_state` — vectorized large-fleet constructor (same
  tier distributions, no per-device Python objects; the 1M-device path).
* :func:`fleet_round_cost` / :func:`fleet_cost_matrix` — batched Eq. 5/7
  (time, energy) per device (× submodel for the matrix form).
* :func:`fleet_affordability` — ``[n, M+1]`` bool action mask (abstain
  always legal, dead devices can only abstain).
* :func:`fleet_charge` — deduct round energy, kill over-committed devices;
  returns ``(new_fleet, ok[n])``.
* :func:`fleet_topk_mask` — jit/shard-friendly bool mask of the top-k
  scores (the Top-K participant cut, §4.3.3).
* :func:`fleet_summary` — fixed-width, permutation-invariant global
  summary of the fleet (histograms + totals); the factored QMIX state.
* :func:`summary_width` — its width: ``2 * n_bins + n_models + 5``,
  independent of ``n_devices``.
* :func:`fleet_total_remaining` — Eq. 6 fleet energy ledger (host float).
* :func:`fleet_connect` / :func:`fleet_disconnect` — hot-plug joins and
  not-yet-connected masking (paper §4.2 Step 1).
* :func:`fleet_idle` / :func:`fleet_set_busy` — per-device virtual clocks
  for the async engine.
* :func:`set_modes` — apply eco/normal/turbo power modes fleet-wide.
* ``*_jit`` variants — the same kernels under ``jax.jit`` for the jax
  backend (sharded inputs stay sharded; reductions become all-reduces).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import (BATTERY_JOULES, DEVICE_TIERS, POWER_MODES,
                               DeviceProfile, DeviceState, make_fleet)

Array = Any  # np.ndarray | jax.Array — kernels are backend-generic

# Array fields, in constructor order (tiers/modes are static aux data).
_ARRAY_FIELDS = ("compute", "p_train", "p_com", "bandwidth", "battery",
                 "remaining", "data_size", "mode_compute", "mode_power",
                 "alive", "busy_until", "charge_rate", "tz_phase")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FleetState:
    """Struct-of-arrays fleet: every field is a [n_devices] array.

    ``mode_compute``/``mode_power`` are the POWER_MODES multipliers applied
    to ``compute``/``p_train`` (the MARL "adjust the computing capability"
    knob); ``tiers``/``modes`` keep the human-readable labels as static
    metadata for the DeviceState compatibility view.
    """

    compute: Array            # samples/s at full model, normal mode
    p_train: Array            # W
    p_com: Array              # W
    bandwidth: Array          # bytes/s uplink
    battery: Array            # J capacity
    remaining: Array          # J
    data_size: Array          # L_n local samples
    mode_compute: Array       # POWER_MODES compute multiplier
    mode_power: Array         # POWER_MODES power multiplier
    alive: Array              # bool
    busy_until: Array = None  # per-device virtual clock (sim seconds): the
                              # device is mid-task until this time; <= now
                              # means idle/dispatchable (async round engine)
    charge_rate: Array = None  # harvesting amplitude, J/s (repro.energy
                               # charge profiles; 0 = never recharges)
    tz_phase: Array = None     # time-of-day offset in [0, 1) fractions of a
                               # day — local solar time AND timezone, shared
                               # by solar charge + diurnal availability
    tiers: Tuple[str, ...] = ()
    modes: Tuple[str, ...] = ()

    def __post_init__(self):
        # `remaining is None` happens when jax unflattens internal proxy
        # trees (device_put/tree_map with placeholder leaves) — leave the
        # placeholder structure alone in that case
        if self.remaining is not None:
            xp = jnp if isinstance(self.remaining, jax.Array) else np
            for f in ("busy_until", "charge_rate", "tz_phase"):
                if getattr(self, f) is None:
                    setattr(self, f, xp.zeros(np.shape(self.remaining),
                                              self.remaining.dtype))

    # --- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (tuple(getattr(self, f) for f in _ARRAY_FIELDS),
                (self.tiers, self.modes))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, tiers=aux[0], modes=aux[1])

    def __len__(self) -> int:
        return int(np.shape(self.compute)[0])

    def replace(self, **kw) -> "FleetState":
        return dataclasses.replace(self, **kw)

    # --- conversions (the thin DeviceState compatibility view) -------------
    @classmethod
    def from_devices(cls, devices: Sequence[DeviceState],
                     backend: str = "numpy") -> "FleetState":
        def arr(vals, dtype):
            a = np.asarray(vals, dtype)
            return jnp.asarray(a) if backend == "jax" else a

        modes = tuple(d.mode for d in devices)
        mults = [POWER_MODES[m] for m in modes]
        return cls(
            compute=arr([d.profile.compute for d in devices], np.float64),
            p_train=arr([d.profile.p_train for d in devices], np.float64),
            p_com=arr([d.profile.p_com for d in devices], np.float64),
            bandwidth=arr([d.profile.bandwidth for d in devices], np.float64),
            battery=arr([d.profile.battery for d in devices], np.float64),
            remaining=arr([d.remaining for d in devices], np.float64),
            data_size=arr([d.data_size for d in devices], np.int64),
            mode_compute=arr([m[0] for m in mults], np.float64),
            mode_power=arr([m[1] for m in mults], np.float64),
            alive=arr([d.alive for d in devices], bool),
            tiers=tuple(d.profile.tier for d in devices),
            modes=modes,
        )

    def device_view(self, i: int) -> DeviceState:
        """Fresh DeviceState snapshot of device ``i`` (detached copy)."""
        prof = DeviceProfile(
            tier=self.tiers[i] if self.tiers else "medium",
            compute=float(self.compute[i]), p_train=float(self.p_train[i]),
            p_com=float(self.p_com[i]), bandwidth=float(self.bandwidth[i]),
            battery=float(self.battery[i]))
        return DeviceState(
            profile=prof, remaining=float(self.remaining[i]),
            data_size=int(self.data_size[i]),
            mode=self.modes[i] if self.modes else "normal",
            alive=bool(self.alive[i]))

    def to_devices(self) -> List[DeviceState]:
        return [self.device_view(i) for i in range(len(self))]


def as_fleet_state(devices) -> FleetState:
    """Normalise selector/engine input: FleetState passes through, a
    DeviceState sequence gets the exact-semantics numpy view."""
    if isinstance(devices, FleetState):
        return devices
    return FleetState.from_devices(devices, backend="numpy")


def fleet_is_jax(fleet: FleetState) -> bool:
    """True for jax-backed fleets — callers in per-round hot paths use this
    to pick the jitted kernel variants."""
    return isinstance(fleet.remaining, jax.Array)


def _xp(fleet: FleetState):
    return jnp if isinstance(fleet.remaining, jax.Array) else np


def _aslike(fleet: FleetState, v) -> Array:
    xp = _xp(fleet)
    return xp.asarray(v, dtype=fleet.remaining.dtype)


def make_fleet_state(n: int, seed: int = 0, tier_probs=(0.4, 0.3, 0.3),
                     data_sizes: Optional[List[int]] = None,
                     backend: str = "jax") -> FleetState:
    """SoA analogue of :func:`repro.core.energy.make_fleet` — built from it,
    so the sampled profiles are identical for a given seed."""
    return FleetState.from_devices(
        make_fleet(n, seed, tier_probs, data_sizes), backend=backend)


def sample_fleet_state(n: int, seed: int = 0, tier_probs=(0.4, 0.3, 0.3),
                       data_sizes: Optional[List[int]] = None,
                       backend: str = "jax") -> FleetState:
    """Vectorized large-fleet constructor (the 65k/1M-device path).

    Samples the same tier mix, per-tier jitter and data-size ranges as
    :func:`repro.core.energy.make_fleet`, but with batched numpy draws —
    no per-device ``DeviceState`` objects, so building a 1M-device fleet
    takes milliseconds instead of minutes.  NOT bit-identical to
    ``make_fleet`` for a given seed (different RNG call order); use
    :func:`make_fleet_state` where the scalar-reference parity contract
    matters."""
    rng = np.random.default_rng(seed)
    tier_names = list(DEVICE_TIERS)
    tiers = rng.choice(len(tier_names), size=n, p=list(tier_probs))
    base = np.asarray([DEVICE_TIERS[t] for t in tier_names], np.float64)
    jitter = rng.uniform(0.85, 1.15, size=(n, 3))
    c, pt, pc = (base[tiers] * jitter).T
    if data_sizes is not None:
        ds = np.asarray(data_sizes, np.int64)
    else:
        ds = rng.integers(200, 1200, size=n)
    battery = np.full(n, BATTERY_JOULES)

    def arr(a, dtype):
        a = np.asarray(a, dtype)
        return jnp.asarray(a) if backend == "jax" else a

    return FleetState(
        compute=arr(c, np.float64), p_train=arr(pt, np.float64),
        p_com=arr(pc, np.float64),
        bandwidth=arr(np.full(n, 2.5e6), np.float64),
        battery=arr(battery, np.float64), remaining=arr(battery, np.float64),
        data_size=arr(ds, np.int64),
        mode_compute=arr(np.ones(n), np.float64),
        mode_power=arr(np.ones(n), np.float64),
        alive=arr(np.ones(n, bool), bool),
        tiers=(), modes=())


# ---------------------------------------------------------------------------
# batched Eq. 3–7 kernels
# ---------------------------------------------------------------------------


def fleet_round_cost(fleet: FleetState, model_bytes, model_fraction,
                     local_epochs: int = 5, batch_size: int = 32):
    """Per-device (t_tra, t_com, e_tra, e_com) [n] for ONE submodel —
    vectorized twin of :func:`repro.core.energy.round_cost`."""
    xp = _xp(fleet)
    eff = fleet.compute * fleet.mode_compute / xp.maximum(
        _aslike(fleet, model_fraction), 1e-6)
    t_tra = fleet.data_size * local_epochs / eff
    t_com = 2.0 * _aslike(fleet, model_bytes) / fleet.bandwidth
    e_tra = fleet.p_train * fleet.mode_power * t_tra
    e_com = fleet.p_com * t_com
    return t_tra, t_com, e_tra, e_com


def fleet_cost_matrix(fleet: FleetState, model_sizes, model_fractions,
                      local_epochs: int = 5, batch_size: int = 32):
    """(t_tra, t_com, e_tra, e_com), each [n, M]: every device crossed with
    every submodel in one broadcasted evaluation."""
    xp = _xp(fleet)
    sizes = _aslike(fleet, model_sizes)                      # [M]
    fracs = xp.maximum(_aslike(fleet, model_fractions), 1e-6)
    eff = (fleet.compute * fleet.mode_compute)[:, None] / fracs[None, :]
    t_tra = (fleet.data_size * local_epochs)[:, None] / eff
    t_com = 2.0 * sizes[None, :] / fleet.bandwidth[:, None]
    e_tra = (fleet.p_train * fleet.mode_power)[:, None] * t_tra
    e_com = fleet.p_com[:, None] * t_com
    return t_tra, t_com, e_tra, e_com


def fleet_affordability(fleet: FleetState, model_sizes, model_fractions,
                        local_epochs: int = 5, batch_size: int = 32,
                        budget_left=None):
    """[n, M+1] bool action mask: column m < M is "device can pay for
    submodel m this round" (strict <, matching ``charge``'s survival
    condition), column M ("do not participate") is always legal.  Dead
    devices can only abstain.

    ``budget_left`` (scalar J, optional) is the remaining FLEET-WIDE
    energy budget (repro.energy global-budget scenarios): submodels whose
    cost alone exceeds it are masked out too, so no selector can even
    propose an action the budget cannot cover.  ``None`` (the default)
    traces the exact pre-budget program."""
    xp = _xp(fleet)
    _, _, e_tra, e_com = fleet_cost_matrix(
        fleet, model_sizes, model_fractions, local_epochs, batch_size)
    e_need = e_tra + e_com
    afford = (e_need < fleet.remaining[:, None]) & fleet.alive[:, None]
    if budget_left is not None:
        afford = afford & (e_need <= _aslike(fleet, budget_left))
    abstain = xp.ones((len(fleet), 1), bool)
    return xp.concatenate([afford, abstain], axis=1)


def fleet_charge(fleet: FleetState, e_need: Array, active: Array
                 ) -> Tuple[FleetState, Array]:
    """Deduct ``e_need`` [n] J from every device where ``active`` [n] —
    fleet-wide twin of :func:`repro.core.energy.charge`.

    Returns (new_fleet, ok[n]).  An active device whose remaining energy is
    <= its need attempts the round anyway, wastes the energy, and dies
    (remaining -> 0, alive -> False) — the paper's 'useless training' arm
    of the wooden-barrel effect.  Inactive and already-dead devices are
    untouched."""
    xp = _xp(fleet)
    attempt = xp.asarray(active, bool) & fleet.alive
    ok = attempt & (fleet.remaining > e_need)
    died = attempt & ~ok
    zeros = xp.zeros_like(fleet.remaining)
    remaining = xp.where(ok, fleet.remaining - e_need,
                         xp.where(died, zeros, fleet.remaining))
    return fleet.replace(remaining=remaining, alive=fleet.alive & ~died), ok


def fleet_total_remaining(fleet: FleetState) -> float:
    # jaxlint: allow(host-sync-in-hot-path) -- the documented single-sync accessor; hot paths batch their pulls via device_get instead
    return float(fleet.remaining.sum())


def fleet_connect(fleet: FleetState, start: int,
                  energy_scale: float = 1.0, now: float = 0.0) -> FleetState:
    """Hot-plug (paper §4.2 Step 1): devices [start:] come online with fresh
    (scaled) batteries, idle as of sim time ``now`` (immediately
    dispatchable by the async engine at the join event)."""
    xp = _xp(fleet)
    joins = xp.arange(len(fleet)) >= start
    return fleet.replace(
        remaining=xp.where(joins, fleet.battery * energy_scale,
                           fleet.remaining),
        alive=fleet.alive | joins,
        busy_until=xp.where(joins, _aslike(fleet, now), fleet.busy_until))


# jaxlint: allow(host-sync-in-hot-path) -- host-side dispatch mask by contract; the async engine keeps authoritative float64 clocks on host
def fleet_idle(fleet: FleetState, now: float) -> np.ndarray:
    """[n] bool host-side mask: alive and not mid-task at sim time ``now`` —
    the dispatchable set for the event-driven engine."""
    return (np.asarray(fleet.alive)
            & (np.asarray(fleet.busy_until) <= now + 1e-9))


def fleet_set_busy(fleet: FleetState, indices, until) -> FleetState:
    """Mark ``indices`` busy until the given sim times (task completion);
    backend-generic functional update of the virtual clocks.

    The clocks take the fleet's dtype — float32 on the jax backend (x64
    disabled), whose resolution degrades at large sim times.  The async
    engine therefore keeps its authoritative clocks host-side in float64
    and treats this field as an observability mirror."""
    # jaxlint: allow(host-sync-in-hot-path) -- observability-mirror update: numpy round-trip by design, host clocks are authoritative
    busy = np.asarray(fleet.busy_until).copy()
    busy[np.asarray(indices, np.int64)] = until
    return fleet.replace(busy_until=_aslike(fleet, busy))


def fleet_disconnect(fleet: FleetState, start: int) -> FleetState:
    """Mark devices [start:] as not yet connected (dead, zero energy)."""
    xp = _xp(fleet)
    out = xp.arange(len(fleet)) >= start
    return fleet.replace(
        remaining=xp.where(out, 0.0, fleet.remaining),
        alive=fleet.alive & ~out)


def _index_mask(fleet: FleetState, indices) -> Array:
    """[n] bool mask with True at ``indices`` (host-built, backend-cast)."""
    m = np.zeros(len(fleet), bool)
    m[np.asarray(indices, np.int64)] = True
    return _xp(fleet).asarray(m)


def fleet_kill(fleet: FleetState, indices) -> FleetState:
    """Hard-crash ``indices``: battery spent (remaining -> 0), alive ->
    False — the FaultPlan "crash" arm.  Any energy already deducted for an
    in-flight task stays deducted (it was wasted)."""
    mask = _index_mask(fleet, indices)
    xp = _xp(fleet)
    return fleet.replace(
        remaining=xp.where(mask, 0.0, fleet.remaining),
        alive=fleet.alive & ~mask)


def fleet_set_alive(fleet: FleetState, indices, value: bool) -> FleetState:
    """Set liveness at ``indices`` WITHOUT touching energy — transient
    disconnect (value=False) and rejoin (value=True) keep the battery, in
    contrast to :func:`fleet_kill` / :func:`fleet_connect`."""
    mask = _index_mask(fleet, indices)
    alive = (fleet.alive | mask) if value else (fleet.alive & ~mask)
    return fleet.replace(alive=alive)


def set_modes(fleet: FleetState, modes: Sequence[str]) -> FleetState:
    """Apply per-device power modes (eco/normal/turbo), keeping the
    multiplier arrays and the label metadata consistent."""
    mults = [POWER_MODES[m] for m in modes]
    return fleet.replace(
        mode_compute=_aslike(fleet, [m[0] for m in mults]),
        mode_power=_aslike(fleet, [m[1] for m in mults]),
        modes=tuple(modes))


# ---------------------------------------------------------------------------
# Top-K participant cut + factored global summary (the QMIX factored state)
# ---------------------------------------------------------------------------


def fleet_topk_mask(scores: Array, k: int) -> Array:
    """[n] bool mask selecting the k highest ``scores``.

    jit/shard-friendly (``jax.lax.top_k`` on the jax backend — under a
    sharded fleet GSPMD lowers it to per-shard top-k + a small cross-shard
    merge, never a full-fleet gather).  ``-inf`` scores are never selected
    even when fewer than k finite candidates exist.  Ties break toward the
    lower device index (matching ``np.argsort(kind="stable")`` on negated
    scores, the host-side selector convention)."""
    n = int(np.shape(scores)[0])
    k = max(0, min(int(k), n))
    if k == 0:
        xp = jnp if isinstance(scores, jax.Array) else np
        return xp.zeros(n, bool)
    if isinstance(scores, jax.Array):
        _, idx = jax.lax.top_k(scores, k)           # stable: low index wins ties
        mask = jnp.zeros(n, bool).at[idx].set(True)
        return mask & jnp.isfinite(scores)
    idx = np.argsort(-np.asarray(scores), kind="stable")[:k]
    mask = np.zeros(n, bool)
    mask[idx] = True
    return mask & np.isfinite(scores)


#: histogram resolution of the factored summary (per-feature bin count)
SUMMARY_BINS = 8
#: width of the non-histogram tail of the summary vector
_SUMMARY_TOTALS = 5


def summary_width(n_models: int, n_bins: int = SUMMARY_BINS) -> int:
    """Width of :func:`fleet_summary`'s output: battery + capability
    histograms (``n_bins`` each), per-submodel affordability fractions
    (``n_models``), and 5 fleet totals — independent of ``n_devices``."""
    return 2 * n_bins + int(n_models) + _SUMMARY_TOTALS


def _histogram(values: Array, weights: Array, lo: float, hi: float,
               n_bins: int, xp) -> Array:
    """Weighted histogram of ``values`` over ``n_bins`` equal bins spanning
    [lo, hi), as a one-hot segment-reduction: ``[n, n_bins]`` one-hot ×
    weights, summed over the fleet axis.  Under a sharded fleet this is one
    ``[n_bins]``-sized all-reduce — the whole point of the factored state:
    no gather of per-device rows ever happens."""
    idx = xp.clip(((values - lo) / (hi - lo) * n_bins).astype(jnp.int32
                                                             if xp is jnp
                                                             else np.int64),
                  0, n_bins - 1)
    onehot = idx[:, None] == xp.arange(n_bins)[None, :]
    return (onehot * weights[:, None]).sum(axis=0)


def fleet_summary(fleet: FleetState, model_sizes, model_fractions,
                  round_idx=0, n_rounds: int = 1, local_epochs: int = 5,
                  batch_size: int = 32, n_bins: int = SUMMARY_BINS,
                  afford: Optional[Array] = None) -> Array:
    """Fixed-width, permutation-invariant global fleet summary — the
    factored QMIX mixer state (``state_mode="factored"``).

    Replaces the flat ``n_devices * OBS_DIM`` observation concatenation
    with ``summary_width(len(model_sizes), n_bins)`` features whose width
    is independent of fleet size:

    * battery histogram — alive-mass per ``remaining/battery`` bin, as a
      fraction of the fleet;
    * capability histogram — alive-mass per effective-compute bin (same
      ``/500`` normalisation as the per-agent observation, Eq. 9);
    * affordability fractions — per submodel m, the fraction of the fleet
      that could pay for m this round (the global view of the paper's
      §4.2 Step 3 energy constraint, priced per the family's cost model);
    * totals — remaining/battery energy ratio (Eq. 6), alive fraction,
      mean battery fraction and mean data size over alive devices, and
      the round-phase feature ``t / n_rounds``.

    Every feature is a sum/mean over the fleet axis, so the summary is
    permutation-invariant over device order and, on a sharded fleet, costs
    one small all-reduce instead of a full-fleet gather.

    ``afford`` accepts a precomputed ``[n, M+1]`` affordability mask so a
    caller that already built the MARL action mask (the selector hot path)
    does not pay the dominant O(n*M) cost kernel twice."""
    xp = _xp(fleet)
    n = len(fleet)
    alive = fleet.alive.astype(fleet.remaining.dtype)
    n_alive = xp.maximum(alive.sum(), 1.0)
    inv_n = 1.0 / float(n)
    batt_frac = fleet.remaining / fleet.battery
    hist_b = _histogram(batt_frac, alive, 0.0, 1.0 + 1e-9, n_bins, xp) * inv_n
    eff = fleet.compute * fleet.mode_compute / 500.0
    hist_c = _histogram(eff, alive, 0.0, 2.0, n_bins, xp) * inv_n
    if afford is None:
        afford = fleet_affordability(fleet, model_sizes, model_fractions,
                                     local_epochs, batch_size)
    afford_frac = afford[:, :-1].astype(batt_frac.dtype).sum(axis=0) * inv_n
    t = xp.asarray(round_idx, batt_frac.dtype) / max(int(n_rounds), 1)
    totals = xp.stack([
        fleet.remaining.sum() / fleet.battery.sum(),
        alive.sum() * inv_n,
        (batt_frac * alive).sum() / n_alive,
        (fleet.data_size * alive).sum() / n_alive / 1000.0,
        t,
    ])
    out = xp.concatenate([hist_b, hist_c, afford_frac, totals])
    return out.astype(jnp.float32 if xp is jnp else np.float32)


# Array fields :func:`fleet_summary` does NOT read directly — blessed for
# the ``pytree-field-coverage`` jaxlint rule.  p_train/p_com/bandwidth
# enter the summary only through the fleet_affordability cost kernel;
# mode_power prices energy rather than capability; busy_until is the async
# engine's observability mirror (its authoritative clocks live host-side);
# charge_rate/tz_phase are static scenario parameters (repro.energy) whose
# EFFECT the summary already sees through the battery histogram — reading
# them here would also change the summary width/values and break the
# bit-for-bit default-path contract.
SUMMARY_EXCLUDED_FIELDS = ("p_train", "p_com", "bandwidth", "mode_power",
                           "busy_until", "charge_rate", "tz_phase")


# Jitted entry points for the jax backend.  local_epochs/batch_size trace as
# scalars; model_sizes/model_fractions as float tuples (leaves).  FleetState
# flows through as a pytree.
fleet_cost_matrix_jit = jax.jit(fleet_cost_matrix)
fleet_affordability_jit = jax.jit(fleet_affordability)
fleet_charge_jit = jax.jit(fleet_charge)
fleet_summary_jit = jax.jit(fleet_summary,
                            static_argnames=("n_rounds", "n_bins"))
