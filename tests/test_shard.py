"""Sharded FleetState (repro.sharding.fleet) vs single-placement parity.

Most tests here need a multi-device runtime; the shard-smoke CI job (and
``benchmarks/fleet_shard_bench.py``) force one on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  Under the default
single-device tier-1 run they skip — except the subprocess test at the
bottom, which spawns a fresh interpreter with the flag set so the
sharded-vs-unsharded equivalence contract is exercised by tier-1 too
(``slow``-marked: it pays a second jax startup).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.fleet import (fleet_affordability_jit, fleet_charge_jit,
                              fleet_summary_jit, make_fleet_state)
from repro.sharding.fleet import (FLEET_AXIS, fleet_mesh, fleet_spec_for,
                                  is_sharded, maybe_shard_fleet, shard_fleet,
                                  unshard_fleet)

SIZES = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
FRACS = (0.11, 0.3, 0.72, 1.0)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs a multi-device runtime (shard-smoke CI sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def test_maybe_shard_noop_below_two_shards():
    fleet = make_fleet_state(8, seed=0, backend="jax")
    assert maybe_shard_fleet(fleet, 0) is fleet
    assert maybe_shard_fleet(fleet, 1) is fleet
    assert not is_sharded(fleet)


def test_single_device_mesh_spec():
    mesh = fleet_mesh(1)
    # trivially divisible: the fleet axis still names the placement
    assert fleet_spec_for("remaining", (32,), mesh) == \
        jax.sharding.PartitionSpec(FLEET_AXIS)
    assert fleet_spec_for("scalar", (), mesh) == jax.sharding.PartitionSpec()


@multi_device
def test_shard_fleet_placement_and_divisibility_fallback():
    mesh = fleet_mesh()
    n_dev = mesh.shape[FLEET_AXIS]
    fleet = shard_fleet(make_fleet_state(16 * n_dev, 0, backend="jax"), mesh)
    assert is_sharded(fleet)
    assert len(fleet.remaining.sharding.device_set) == n_dev
    # indivisible fleet dim falls back to replication instead of erroring
    odd = shard_fleet(make_fleet_state(16 * n_dev + 1, 0, backend="jax"),
                      mesh)
    assert odd.remaining.sharding.is_fully_replicated
    # round-trip back to host numpy
    back = unshard_fleet(fleet)
    assert isinstance(back.remaining, np.ndarray)
    np.testing.assert_array_equal(back.remaining,
                                  np.asarray(fleet.remaining))


@multi_device
def test_sharded_kernels_match_single_placement():
    n = 32 * len(jax.devices())
    single = make_fleet_state(n, seed=5, backend="jax")
    single = single.replace(remaining=single.battery * 0.05)
    sharded = shard_fleet(single, fleet_mesh())

    aff_s = np.asarray(fleet_affordability_jit(single, SIZES, FRACS, 5, 32))
    aff_p = np.asarray(fleet_affordability_jit(sharded, SIZES, FRACS, 5, 32))
    np.testing.assert_array_equal(aff_s, aff_p)

    need = np.linspace(0.0, 400.0, n).astype(np.float32)
    active = (np.arange(n) % 3 != 1)
    f_s, ok_s = fleet_charge_jit(single, need, active)
    f_p, ok_p = fleet_charge_jit(sharded, need, active)
    assert is_sharded(f_p)                 # sharding survives the kernel
    np.testing.assert_array_equal(np.asarray(ok_s), np.asarray(ok_p))
    np.testing.assert_allclose(np.asarray(f_s.remaining),
                               np.asarray(f_p.remaining), rtol=1e-6)

    s_s = np.asarray(fleet_summary_jit(single, SIZES, FRACS, 2, n_rounds=10))
    s_p = np.asarray(fleet_summary_jit(sharded, SIZES, FRACS, 2,
                                       n_rounds=10))
    np.testing.assert_allclose(s_s, s_p, rtol=1e-5, atol=1e-6)


@multi_device
def test_sharded_dual_selection_step_equivalence():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.marl.networks import agent_hidden_init, agent_init
    from repro.core.selection import OBS_DIM, dual_selection_energy_step_jit
    mesh = fleet_mesh()
    n = 64 * mesh.shape[FLEET_AXIS]
    fleet = make_fleet_state(n, seed=2, backend="jax")
    params = agent_init(jax.random.PRNGKey(0), OBS_DIM, len(SIZES) + 1)
    hidden = agent_hidden_init(n)
    args = (SIZES, FRACS)

    f1, h1, part1, act1, sum1 = dual_selection_energy_step_jit(
        params, hidden, fleet, *args, k=8, n_rounds=10)
    f2, h2, part2, act2, sum2 = dual_selection_energy_step_jit(
        params, jax.device_put(hidden, NamedSharding(mesh, P(FLEET_AXIS))),
        shard_fleet(fleet, mesh), *args, k=8, n_rounds=10)
    np.testing.assert_array_equal(np.asarray(part1), np.asarray(part2))
    np.testing.assert_array_equal(np.asarray(act1), np.asarray(act2))
    np.testing.assert_allclose(np.asarray(f1.remaining),
                               np.asarray(f2.remaining), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sum1), np.asarray(sum2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-6)


@multi_device
def test_engine_runs_on_sharded_fleet():
    """fleet_mesh=-1 threads through build_world: the whole sync engine
    runs with the fleet row-sharded (host code gathers transparently)."""
    from repro.fl import FLConfig, run_simulation
    from repro.fl.engine import build_world
    cfg = FLConfig(n_devices=16, n_rounds=2, participation=0.5, n_train=400,
                   local_epochs=1, method="drfl", selector="greedy", seed=0,
                   fleet_mesh=-1)
    assert is_sharded(build_world(cfg).fleet)
    h = run_simulation(cfg)
    ref = run_simulation(FLConfig(**{**cfg.__dict__, "fleet_mesh": 0}))
    assert h["participants"] == ref["participants"]
    assert h["acc_mean"] == ref["acc_mean"]


@multi_device
def test_trained_set_selector_step_matches_on_mesh():
    """A TRAINED set-mixer selector's jitted update is placement-invariant:
    the same replay batch produces the same loss/params whether the
    episode was traced against a single-placement or a mesh-sharded fleet
    (shard_agent_array handles the companion [n, ...] arrays)."""
    from repro.core.marl.buffer import ReplayBuffer
    from repro.core.selection import OBS_DIM, MarlSelector
    from repro.sharding.fleet import shard_agent_array

    mesh = fleet_mesh()
    n = 64 * mesh.shape[FLEET_AXIS]

    def run(shard):
        sel = MarlSelector(n, len(SIZES), n_rounds=3, seed=0,
                           state_mode="factored", mixer_mode="set",
                           agent_budget=16)
        fleet = make_fleet_state(n, seed=2, backend="jax")
        if shard:
            fleet = shard_fleet(fleet, mesh)
            sel.hidden = shard_agent_array(sel.hidden, mesh)
        buf = ReplayBuffer(4, 3, n, OBS_DIM, sel.learner.cfg.state_dim, 0,
                           agent_budget=16)
        for t in range(3):
            sel.select(fleet, t, 8, SIZES, FRACS)
            sel.observe_reward(1.0)
        buf.add_episode(*sel.episode_arrays(fleet, 3))
        return sel.learner.update(buf.sample(4))["td_loss"]

    loss_single, loss_sharded = run(False), run(True)
    np.testing.assert_allclose(loss_single, loss_sharded,
                               rtol=1e-5, atol=1e-6)


@multi_device
def test_shard_agent_array_placement_and_fallback():
    from repro.sharding.fleet import shard_agent_array
    mesh = fleet_mesh()
    n_dev = mesh.shape[FLEET_AXIS]
    x = np.zeros((16 * n_dev, 64), np.float32)
    placed = shard_agent_array(x, mesh)
    assert len(placed.sharding.device_set) == n_dev
    assert not placed.sharding.is_fully_replicated
    odd = shard_agent_array(np.zeros((16 * n_dev + 1, 64), np.float32), mesh)
    assert odd.sharding.is_fully_replicated


def test_dual_selection_step_one_executable_per_shape():
    """The sharded hot-path step must reuse ONE executable across rounds of
    the same shape (round_idx is traced, k/n_rounds are static) — the
    runtime complement to the static retrace-hazard lint rule."""
    from repro.analysis.runtime import cache_size, compile_guard
    from repro.core.marl.networks import agent_hidden_init, agent_init
    from repro.core.selection import OBS_DIM, dual_selection_energy_step_jit

    n = 16
    fleet = make_fleet_state(n, seed=3, backend="jax")
    params = agent_init(jax.random.PRNGKey(0), OBS_DIM, len(SIZES) + 1)
    f, h, *_ = dual_selection_energy_step_jit(
        params, agent_hidden_init(n), fleet, SIZES, FRACS, k=4,
        round_idx=0, n_rounds=8)
    if cache_size(dual_selection_energy_step_jit) == 0:
        pytest.skip("jit wrapper does not expose _cache_size")
    with compile_guard(dual_selection_energy_step_jit, max_new=0):
        for r in range(1, 5):
            f, h, *_ = dual_selection_energy_step_jit(
                params, h, f, SIZES, FRACS, k=4, round_idx=r, n_rounds=8)
    # a NEW fleet shape is allowed exactly one fresh executable
    with compile_guard(dual_selection_energy_step_jit, max_new=1):
        dual_selection_energy_step_jit(
            params, agent_hidden_init(2 * n),
            make_fleet_state(2 * n, seed=4, backend="jax"), SIZES, FRACS,
            k=4, round_idx=0, n_rounds=8)


# ---------------------------------------------------------------------------
# tier-1 coverage under the default single-device runtime
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_equivalence_in_forced_multidevice_subprocess():
    """Spawns a fresh interpreter with a forced 4-device CPU mesh and runs
    the kernel-equivalence checks there, so tier-1 exercises the sharded
    path even though this process owns a single device."""
    code = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.fleet import make_fleet_state, fleet_summary_jit, \\
            fleet_charge_jit
        from repro.sharding.fleet import fleet_mesh, shard_fleet, is_sharded
        SIZES = (2.8e6, 8.4e6, 22.5e6, 44.8e6)
        FRACS = (0.11, 0.3, 0.72, 1.0)
        single = make_fleet_state(64, seed=5, backend="jax")
        sharded = shard_fleet(single, fleet_mesh())
        assert is_sharded(sharded)
        s1 = np.asarray(fleet_summary_jit(single, SIZES, FRACS, 1,
                                          n_rounds=4))
        s2 = np.asarray(fleet_summary_jit(sharded, SIZES, FRACS, 1,
                                          n_rounds=4))
        np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-6)
        need = np.linspace(0, 300, 64).astype(np.float32)
        f1, ok1 = fleet_charge_jit(single, need, np.ones(64, bool))
        f2, ok2 = fleet_charge_jit(sharded, need, np.ones(64, bool))
        np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
        np.testing.assert_allclose(np.asarray(f1.remaining),
                                   np.asarray(f2.remaining), rtol=1e-6)
        print("SHARDED-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED-OK" in out.stdout
