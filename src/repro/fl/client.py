"""FL client-side local training (paper Step 5).

Clients train with SGD + cross-entropy on their local shard.  Three client
kinds mirror the three methods under comparison:

* ``drfl_client_update``    — depth-prefix submodel (loss at exit m; grads
  are exactly zero outside the submodel, so the returned full-structure
  delta is already "zero-filled" for layer-aligned aggregation).
* ``heterofl_client_update`` — width-sliced submodel (HeteroFL).
* ``scalefl_client_update``  — depth+width submodel with self-distillation.

Each kind jits one program per submodel index — shapes are static per index,
so 4 programs cover the whole fleet.

This is the PER-CLIENT path (one dispatch per mini-batch): small fleets use
it directly, and it is the parity reference for the bucketed-vmap executor
(:mod:`repro.fl.batch`) that large fleets run — both train the same
per-method losses exported below.  Per-step losses accumulate on device and
sync to the host ONCE per client (:func:`_mean_loss`).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import kd_loss, scalefl_submodel, width_slice_cnn, WIDTH_LEVELS
from repro.data.loader import epoch_batches
from repro.models import cnn


def client_update_seed(base_seed: int, round_idx: int, device_idx: int) -> int:
    """Collision-free per-(round, device) seed for local training.

    The old ``base*1000 + t*100 + i`` mix collided across rounds for any
    ``i >= 100`` (every 100+ device fleet), silently correlating client
    batch orders.  ``SeedSequence`` hashes the entropy tuple, so distinct
    (base, round, device) triples map to distinct, well-mixed streams."""
    return int(np.random.SeedSequence(
        entropy=(int(base_seed), int(round_idx), int(device_idx))
    ).generate_state(1)[0])


def _ce(logits, y):
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - tgt)


# ---------------------------------------------------------------------------
# per-method local losses, shared verbatim by the per-client steps below and
# the bucketed-vmap executor (repro.fl.batch) so both paths train the same
# objective on the same submodel tree
# ---------------------------------------------------------------------------


def drfl_submodel_loss(sub, x, y):
    """Joint CE over every exit the submodel holds (BranchyNet-style deep
    supervision — each of the paper's layer-wise models carries a bottleneck
    + classifier per block, so shallow exits keep learning on deep clients
    and layer-aligned aggregation stays useful for Model_1..Model_m).
    The deepest held exit carries full weight; shallower exits get 0.3."""
    outs = cnn.apply_all_exits(sub, x)
    loss = _ce(outs[-1], y)
    for o in outs[:-1]:
        loss = loss + 0.3 * _ce(o, y)
    return loss / (1.0 + 0.3 * (len(outs) - 1))


def slice_submodel_loss(sub, x, y):
    """Width-sliced trees (HeteroFL): loss at the tree's deepest exit."""
    outs = cnn.apply_all_exits(sub, x)
    return _ce(outs[-1], y)


def scalefl_submodel_loss(sub, x, y):
    """Depth+width tree; CE at every held exit + KD deepest->shallower."""
    outs = cnn.apply_all_exits(sub, x)
    teacher = outs[-1]
    loss = _ce(teacher, y)
    for s in outs[:-1]:
        loss = loss + 0.5 * (_ce(s, y) + kd_loss(s, jax.lax.stop_gradient(teacher)))
    return loss / max(len(outs), 1)


@functools.partial(jax.jit, static_argnums=(3,))
def _drfl_sgd_step(params, x, y, model_idx: int, lr: float = 0.05):
    def loss_fn(p):
        sub = {"stem": p["stem"], "stages": p["stages"][:model_idx + 1],
               "exits": p["exits"][:model_idx + 1]}
        return drfl_submodel_loss(sub, x, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


@jax.jit
def _slice_sgd_step(params, x, y, lr: float = 0.05):
    loss, grads = jax.value_and_grad(slice_submodel_loss)(params, x, y)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


@jax.jit
def _scalefl_sgd_step(params, x, y, lr: float = 0.05):
    loss, grads = jax.value_and_grad(scalefl_submodel_loss)(params, x, y)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def _mean_loss(losses) -> float:
    """ONE host sync for the whole local run: the per-step device scalars
    stay un-synced (jax dispatch keeps streaming) and are reduced on device;
    only the final mean crosses to the host."""
    if not losses:
        return 0.0
    return float(jnp.mean(jnp.stack(losses)))


def _run_epochs(step_fn, params, x, y, epochs, batch, rng, lr):
    losses = []
    for _ in range(epochs):
        for xb, yb in epoch_batches(x, y, batch, rng):
            params, l = step_fn(params, jnp.asarray(xb), jnp.asarray(yb), lr)
            losses.append(l)
    return params, _mean_loss(losses)


def drfl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                       batch=32, lr=0.05, seed=0) -> Tuple[Dict, float]:
    """Returns (delta pytree full structure, mean local loss)."""
    rng = np.random.default_rng(seed)
    params = global_params
    losses = []
    for _ in range(epochs):
        for xb, yb in epoch_batches(x, y, batch, rng):
            params, l = _drfl_sgd_step(params, jnp.asarray(xb), jnp.asarray(yb),
                                       model_idx, lr)
            losses.append(l)
    delta = jax.tree.map(lambda a, b: a - b, params, global_params)
    return delta, _mean_loss(losses)


def heterofl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                           batch=32, lr=0.05, seed=0):
    """Returns (sliced delta, mean loss); slice width = WIDTH_LEVELS[idx]."""
    frac = WIDTH_LEVELS[model_idx]
    sub = width_slice_cnn(global_params, frac)
    rng = np.random.default_rng(seed)
    new, loss = _run_epochs(_slice_sgd_step, sub, x, y, epochs, batch, rng, lr)
    delta = jax.tree.map(lambda a, b: a - b, new, sub)
    return delta, loss


def scalefl_client_update(global_params, model_idx: int, x, y, *, epochs=5,
                          batch=32, lr=0.05, seed=0):
    sub = scalefl_submodel(global_params, model_idx)
    rng = np.random.default_rng(seed)
    new, loss = _run_epochs(_scalefl_sgd_step, sub, x, y, epochs, batch, rng, lr)
    delta = jax.tree.map(lambda a, b: a - b, new, sub)
    return delta, loss
