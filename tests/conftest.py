import os
import sys

# Make `pytest tests/` work without PYTHONPATH=src (and never set XLA device
# flags here — smoke tests must see exactly 1 CPU device; the dry-run tests
# spawn subprocesses with their own DRYRUN_DEVICES).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
