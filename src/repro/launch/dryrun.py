import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("DRYRUN_DEVICES", "512")
    + ((" " + os.environ["DRYRUN_EXTRA_XLA_FLAGS"])
       if "DRYRUN_EXTRA_XLA_FLAGS" in os.environ else ""))

"""Multi-pod dry-run: prove every (architecture × input-shape × mesh)
combination lowers AND compiles under the production sharding config.

The two lines above MUST stay first — jax locks the device count on first
initialisation, and the production meshes need 512 placeholder host devices
(set DRYRUN_DEVICES to shrink for in-test debug meshes).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k --mesh single [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.specs import (decode_cache_shardings, decode_inputs,
                                params_shardings, state_shardings,
                                train_input_shardings, train_inputs,
                                _params_shape, batch_spec)
from repro.launch.steps import (TrainConfig, adapt_for_shape,
                                build_fl_bucketed_train_step,
                                build_fl_train_step, build_prefill_step,
                                build_serve_step, build_train_step,
                                fl_batch_extras, train_state_shape)
from repro.sharding.rules import set_activation_mesh
from jax.sharding import NamedSharding, PartitionSpec as P


def make_mesh(kind: str):
    if kind == "single":
        return make_production_mesh(multi_pod=False)
    if kind == "multi":
        return make_production_mesh(multi_pod=True)
    if kind == "debug":
        return make_debug_mesh(multi_pod=False)
    if kind == "debug-multi":
        return make_debug_mesh(multi_pod=True)
    raise ValueError(kind)


def dryrun_one(arch: str, shape_name: str, mesh_kind: str,
               verbose: bool = True, tcfg: TrainConfig = None,
               step_kind: str = "default", moe_decode: str = None):
    cfg = adapt_for_shape(get_config(arch), INPUT_SHAPES[shape_name])
    if moe_decode:
        cfg = dataclasses.replace(cfg, moe_decode_impl=moe_decode)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_mesh(mesh_kind)
    tcfg = tcfg or TrainConfig()
    t0 = time.time()

    with mesh:
        set_activation_mesh(mesh)
        try:
            if shape.kind == "train":
                if step_kind == "fl":
                    model, step = build_fl_train_step(cfg, tcfg)
                elif step_kind == "fl-bucketed":
                    model, step, nb = build_fl_bucketed_train_step(cfg, tcfg)
                else:
                    model, step = build_train_step(cfg, tcfg)
                state_shp = train_state_shape(model, tcfg)
                inputs = train_inputs(cfg, shape)
                in_batch_sh = train_input_shardings(cfg, shape, mesh)
                if step_kind == "fl-bucketed":
                    B, S = shape.global_batch, shape.seq_len
                    bsp = batch_spec(mesh)
                    row_axes = tuple(a for a in ("pod", "data", "model")
                                     if a in mesh.axis_names
                                     and (B // nb) % mesh.shape[a] == 0)
                    # greedily use axes that divide the per-bucket rows
                    rows = B // nb
                    used, prod = [], 1
                    for a in row_axes:
                        if rows % (prod * mesh.shape[a]) == 0:
                            used.append(a)
                            prod *= mesh.shape[a]
                    for kk in ("tokens", "labels"):
                        inputs[kk] = jax.ShapeDtypeStruct(
                            (nb, B // nb, S), inputs[kk].dtype)
                        in_batch_sh[kk] = NamedSharding(
                            mesh, P(None, tuple(used) if len(used) != 1
                                    else used[0], None))
                if step_kind == "fl":
                    extras = fl_batch_extras(cfg, shape)
                    inputs.update(extras)
                    bsp = batch_spec(mesh)
                    in_batch_sh["layer_gates"] = NamedSharding(
                        mesh, P(None, *bsp))
                    in_batch_sh["layer_counts"] = NamedSharding(mesh, P())
                    in_batch_sh["n_clients"] = NamedSharding(mesh, P())
                in_sh = (state_shardings(state_shp, mesh), in_batch_sh)
                # jaxlint: allow(retrace-hazard) -- per-shape AOT lower/compile IS the dryrun's product
                lowered = jax.jit(
                    step, in_shardings=in_sh,
                    out_shardings=(in_sh[0], None),
                    donate_argnums=(0,),
                ).lower(state_shp, inputs)
            elif shape.kind == "prefill":
                model, step = build_prefill_step(cfg, tcfg)
                pshp = _params_shape(model)
                in_sh = (params_shardings(pshp, mesh),
                         train_input_shardings(cfg, shape, mesh))
                inputs = train_inputs(cfg, shape)
                inputs.pop("labels")
                in_sh[1].pop("labels", None)
                # jaxlint: allow(retrace-hazard) -- per-shape AOT lower/compile IS the dryrun's product
                lowered = jax.jit(step, in_shardings=in_sh).lower(pshp, inputs)
            else:  # decode
                set_activation_mesh(mesh, model_axis_ok=False)
                model, step = build_serve_step(cfg)
                pshp = _params_shape(model)
                cache_shp, tok, pos = decode_inputs(model, cfg, shape)
                bsp = batch_spec(mesh)
                in_sh = (params_shardings(pshp, mesh),
                         decode_cache_shardings(cache_shp, mesh),
                         NamedSharding(mesh, P(*bsp, None))
                         if shape.global_batch > 1 else
                         NamedSharding(mesh, P(None, None)),
                         NamedSharding(mesh, P()))
                # jaxlint: allow(retrace-hazard) -- per-shape AOT lower/compile IS the dryrun's product
                lowered = jax.jit(step, in_shardings=in_sh,
                                  donate_argnums=(1,)).lower(
                    pshp, cache_shp, tok, pos)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        finally:
            set_activation_mesh(None)

    mem = H.memory_stats(compiled)
    terms = H.roofline_terms(compiled)
    mf = H.model_flops_per_step(cfg, shape)
    n_dev = mesh.devices.size
    terms["model_flops_per_device"] = mf / n_dev
    terms["useful_flops_ratio"] = (mf / n_dev) / max(terms["hlo_flops_per_device"], 1.0)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "step": step_kind,
        "devices": int(n_dev), "kind": shape.kind,
        "window_override": cfg.window if cfg.window else 0,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "roofline": terms, "ok": True,
    }
    if verbose:
        gb = mem.get("total_hbm_bytes", 0) / 2**30
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"hbm/device={gb:.2f}GiB dominant={terms['dominant']} "
              f"t_comp={terms['t_compute_s']:.4g}s t_mem={terms['t_memory_s']:.4g}s "
              f"t_coll={terms['t_collective_s']:.4g}s "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print("  memory_analysis:", {k: round(v / 2**30, 3) for k, v in mem.items()})
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print("  cost_analysis: flops=%.4g bytes=%.4g" % (
            float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "debug", "debug-multi"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--step", default="default",
                    choices=["default", "fl", "fl-bucketed"],
                    help="fl = DR-FL-over-pods masked train step; fl-bucketed "
                         "= statically depth-bucketed variant (train shapes)")
    ap.add_argument("--attn-chunk", type=int, default=0,
                    help=">0: online-softmax KV-block attention (perf knob)")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate weights over the data axis (pure TP+DP)")
    ap.add_argument("--no-act-model", action="store_true",
                    help="keep the residual stream replicated on the model axis")
    ap.add_argument("--repeat-kv", action="store_true",
                    help="materialise repeated KV heads (shardable Q-head axis)")
    ap.add_argument("--zero1", action="store_true",
                    help="with --no-fsdp: shard optimizer moments over data")
    ap.add_argument("--attn-seq", action="store_true",
                    help="context-parallel attention (Q sequence-sharded)")
    ap.add_argument("--attn-heads", action="store_true",
                    help="pad-shard the attention head axis (with --repeat-kv)")
    ap.add_argument("--act-seq", action="store_true",
                    help="sequence-parallel residual stream (Megatron-style)")
    ap.add_argument("--block-gather", action="store_true",
                    help="bf16 all-gather of the residual at block entry")
    ap.add_argument("--dp2d", action="store_true",
                    help="2-D data parallelism: batch over (data x model)")
    ap.add_argument("--moe-decode", default=None, choices=["gather", "dispatch"],
                    help="MoE decode path (perf knob)")
    ap.add_argument("--json", default=None, help="write results to this path")
    args = ap.parse_args(argv)

    from repro.sharding.rules import set_sharding_policy
    set_sharding_policy(fsdp=not args.no_fsdp, act_model=not args.no_act_model,
                        repeat_kv=args.repeat_kv, zero1=args.zero1,
                        attn_seq=args.attn_seq, attn_heads=args.attn_heads,
                        act_seq=args.act_seq, block_gather=args.block_gather,
                        dp2d=args.dp2d)
    tcfg = TrainConfig(attn_chunk=args.attn_chunk, remat=args.remat)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                try:
                    results.append(dryrun_one(arch, shape, mk, tcfg=tcfg, step_kind=args.step,
                                              moe_decode=args.moe_decode))
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape, "mesh": mk,
                                    "ok": False, "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[dryrun] wrote {len(results)} results to {args.json}")
    print(f"[dryrun] {len(results) - failures}/{len(results)} combinations OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
