"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device.

Production target: TPU v5e-class pods, 256 chips each.
  single-pod: (16, 16)    axes (data, model)
  multi-pod:  (2, 16, 16) axes (pod, data, model)   # 512 chips

The ``pod`` axis doubles as the DR-FL *client* axis in the federated
multi-pod mapping (see repro.core.aggregation.fl_allreduce).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for in-test dry-runs (requires >=8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
