"""repro.analysis — jaxlint: repo-aware static analysis for the DR-FL
stack, plus runtime compile guards.

Entry points:

* ``python -m repro.analysis`` / ``scripts/jaxlint.py`` — run the lint.
* :func:`repro.analysis.lint.run_lint` — programmatic API.
* :mod:`repro.analysis.runtime` — ``compile_guard`` for tests.

See ``docs/ANALYSIS.md`` for the rule catalogue and pragma syntax.
"""
from .core import BAD_PRAGMA, Finding, RepoIndex
from .lint import LintConfig, Report, run_lint, write_json
from .runtime import compile_guard

__all__ = ["BAD_PRAGMA", "Finding", "RepoIndex", "LintConfig", "Report",
           "run_lint", "write_json", "compile_guard"]
