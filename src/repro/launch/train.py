"""Production training launcher.

On a real TPU pod this binary runs under the usual multi-host bootstrap
(one process per host; jax.distributed.initialize picks up the pod runtime).
On CPU it runs the same code path over the reduced configs.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 20 --batch 8 --seq 64

    # production shape (pairs with the dry-run sharding config):
    python -m repro.launch.train --arch yi-34b --shape train_4k \
        --mesh single --steps 100 --ckpt-dir /ckpt/yi34b

DR-FL-over-pods: ``--fl-clients N`` assigns each client a depth-prefix
submodel (round-robin over the 4 exits) and layer-align aggregates deltas
every ``--fl-agg-every`` steps — the paper's Step 2 running inside the
distributed training loop.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_pytree, save_pytree
from repro.configs import (INPUT_SHAPES, TrainConfig, get_config,
                           get_smoke_config)
from repro.data.synthetic import lm_batches, synthetic_lm_dataset
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import state_shardings, train_input_shardings
from repro.launch.steps import adapt_for_shape, build_train_step
from repro.models import extra_inputs
from repro.optim import adamw_init
from repro.sharding.rules import set_activation_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fl-clients", type=int, default=0)
    ap.add_argument("--fl-agg-every", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.shape:
        shape = INPUT_SHAPES[args.shape]
        cfg = adapt_for_shape(cfg, shape)
        B, S = shape.global_batch, shape.seq_len
    else:
        B, S = args.batch, args.seq
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps, remat=args.remat,
                       loss_chunk=min(512, S), use_pallas=args.use_pallas)
    model, train_step = build_train_step(cfg, tcfg)

    mesh = None
    if args.mesh:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        set_activation_mesh(mesh)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    start = 0
    if args.ckpt_dir:
        ck = latest_step(args.ckpt_dir)
        if ck:
            state = load_pytree(ck, state)
            start = int(np.asarray(state["opt"]["step"]))
            print(f"resumed from {ck} (step {start})")

    if mesh is not None:
        shardings = state_shardings(jax.eval_shape(lambda: state), mesh)
        # jaxlint: allow(retrace-hazard) -- jitted once at process startup
        step_fn = jax.jit(train_step, in_shardings=(shardings, None),
                          out_shardings=(shardings, None), donate_argnums=(0,))
    else:
        # jaxlint: allow(retrace-hazard) -- jitted once at process startup
        step_fn = jax.jit(train_step, donate_argnums=(0,))

    toks = synthetic_lm_dataset(max(S * B * 4, 100_000), cfg.vocab_size, seed=0)
    it = lm_batches(toks, B, S, seed=0)
    extras = {k: jnp.zeros(shp, dt) for k, (shp, dt)
              in extra_inputs(cfg, B, S).items()}

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        batch.update(extras)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / max(step - start + 1, 1):.2f}s/step)",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_pytree(args.ckpt_dir, state, step=step + 1)
    if args.ckpt_dir:
        p = save_pytree(args.ckpt_dir, state, step=args.steps)
        print("saved", p)
    set_activation_mesh(None)


if __name__ == "__main__":
    sys.exit(main())
