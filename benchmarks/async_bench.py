"""Sync barrier vs async event engine: straggler wait at scale.

Runs the same DR-FL configuration twice — ``engine_mode="sync"`` and
``engine_mode="async"`` with the sync run's total simulated time as the
async time horizon and the sync-equivalent client-task budget — and
reports, per engine:

* ``sim_time``  — virtual makespan to finish the task budget;
* ``idle``      — straggler wait: how long finished client updates sat
  before entering the global model (the barrier cost; zero-by-construction
  for per-event aggregation, but computed rather than assumed);
* ``tasks`` / ``aggs`` and the async staleness profile.

The acceptance claim (ISSUE 2): at n=256 the async engine finishes the
same simulated-time budget with strictly lower idle time than sync.

    python -m benchmarks.async_bench            # n=256 (also under FAST)
    python -m benchmarks.async_bench 64         # override fleet size
    REPRO_ASYNC_N=512 python -m benchmarks.async_bench
"""
from __future__ import annotations

import dataclasses
import os
import sys

import numpy as np

from benchmarks.common import FAST, Timer, emit
from repro.fl import FLConfig, run_simulation


def base_config(n: int, seed: int = 0) -> FLConfig:
    # tiny data/energy budget: the comparison is about SCHEDULING (virtual
    # time and idle), not accuracy, so client updates stay cheap even at 256
    return FLConfig(n_devices=n, n_rounds=2 if FAST else 8,
                    participation=0.1, n_train=max(1500, 6 * n),
                    local_epochs=1, method="drfl", selector="greedy",
                    seed=seed, energy_scale=0.05)


def main(n: int = 0, seed: int = 0, verbose: bool = False):
    n = int(n or os.environ.get("REPRO_ASYNC_N", 0) or 256)
    cfg = base_config(n, seed)

    with Timer() as tm:
        h_sync = run_simulation(dataclasses.replace(cfg, engine_mode="sync"),
                                verbose=verbose)
    emit(f"async_bench/sync/n{n}", tm.dt * 1e6,
         f"sim_time={h_sync['sim_time_total']:.1f}s "
         f"idle={h_sync['idle_time']:.1f}s aggs={h_sync['n_aggregations']}")

    horizon = h_sync["sim_time_total"]
    with Timer() as tm:
        h_async = run_simulation(
            dataclasses.replace(cfg, engine_mode="async",
                                async_time_horizon=horizon),
            verbose=verbose)
    stale = np.asarray(h_async["staleness"]) if h_async["staleness"] else \
        np.zeros(1)
    emit(f"async_bench/async/n{n}", tm.dt * 1e6,
         f"sim_time={h_async['sim_time_total']:.1f}s "
         f"idle={h_async['idle_time']:.1f}s tasks={h_async['n_tasks']} "
         f"aggs={h_async['n_aggregations']} "
         f"staleness_mean={stale.mean():.2f} staleness_max={stale.max()}")
    emit(f"async_bench/gap/n{n}", 0.0,
         f"idle_sync_minus_async={h_sync['idle_time'] - h_async['idle_time']:.1f}s "
         f"makespan_ratio={h_async['sim_time_total'] / max(horizon, 1e-9):.3f}")
    return {"sync": h_sync, "async": h_async, "horizon": horizon}


if __name__ == "__main__":
    main(n=int(sys.argv[1]) if len(sys.argv) > 1 else 0, verbose=True)
