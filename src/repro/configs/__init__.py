"""Architecture config registry.

``get_config(arch)`` returns the full-size :class:`ModelConfig`;
``get_smoke_config(arch)`` returns the reduced same-family variant used by
CPU smoke tests.  ``--arch`` flags resolve through :data:`REGISTRY`.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (INPUT_SHAPES, ModelConfig, ShapeConfig,
                                TrainConfig, reduced)

_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "yi-34b": "yi_34b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "phi3-mini-3.8b": "phi3_mini",
    "mixtral-8x22b": "mixtral_8x22b",
    "minitron-8b": "minitron_8b",
    "command-r-35b": "command_r_35b",
    "whisper-medium": "whisper_medium",
    # the paper's own backbone (ResNet-18 + 4 exits) lives in drfl_resnet
    "drfl-resnet18": "drfl_resnet",
}


def list_archs():
    return [a for a in _MODULES if a != "drfl-resnet18"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


REGISTRY: Dict[str, str] = dict(_MODULES)

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "INPUT_SHAPES",
           "get_config", "get_smoke_config", "list_archs", "reduced",
           "REGISTRY"]
